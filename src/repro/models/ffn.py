"""Feed-forward mixers: SwiGLU MLP and MoE.

MoE dispatch has two implementations:

* ``gather`` (baseline): pjit-global sort-based dispatch. Tokens are routed
  into an (E, C, d) buffer with scatter/gather; GSPMD inserts the collectives.
* ``shardmap`` (optimized): activations replicated over the `model` axis,
  experts sharded over `model`; each model-rank dispatches locally into its
  own expert shard and the combine is a single psum — no all-to-all, no
  global gather of the token array. See EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import common as cm


# ---------------------------------------------------------------------------
# Dense SwiGLU
# ---------------------------------------------------------------------------

def swiglu_init(key, d_model, d_ff):
    ks = jax.random.split(key, 3)
    return {
        "gate": cm.dense(ks[0], d_model, d_ff, ("embed", "mlp")),
        "up": cm.dense(ks[1], d_model, d_ff, ("embed", "mlp")),
        "down": cm.dense(ks[2], d_ff, d_model, ("mlp", "embed")),
    }


def swiglu(p, x):
    from repro.distributed import sharding as shd
    # 'seq' (not None) in the hidden constrain: under sequence-parallel
    # prefill the activation stays seq-sharded — a None here would force a
    # full-sequence gather AND replicate the up-projection compute.
    axes = ("batch",) + ("seq",) * (x.ndim - 2) + ("mlp",)
    g = shd.constrain(cm.apply_dense(p["gate"], x), axes)
    u = cm.apply_dense(p["up"], x)
    return cm.apply_dense(p["down"], jax.nn.silu(g) * u)


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------

def moe_init(key, cfg):
    moe = cfg.moe
    ks = jax.random.split(key, 5)
    d, ff, e = cfg.d_model, cfg.d_ff, moe.num_experts
    def expert_w(k, d_in, d_out, axes):
        w = jax.random.truncated_normal(k, -2., 2., (e, d_in, d_out)) * (
            1.0 / jnp.sqrt(d_in))
        return {"w": cm.Param(w, ("expert",) + axes)}
    p = {
        "router": cm.dense(ks[0], d, e, ("embed", "expert")),
        "gate": expert_w(ks[1], d, ff, ("embed", "mlp")),
        "up": expert_w(ks[2], d, ff, ("embed", "mlp")),
        "down": expert_w(ks[3], ff, d, ("mlp", "embed")),
    }
    if moe.shared_expert_ff:
        p["shared"] = swiglu_init(ks[4], d, moe.shared_expert_ff)
    return p


def _route(router_p, x2d, moe):
    """x2d: (T, d) -> (weights (T,k), experts (T,k))."""
    logits = cm.apply_dense(router_p, x2d).astype(jnp.float32)   # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    weights, experts = jax.lax.top_k(probs, moe.top_k)
    weights = weights / jnp.maximum(
        jnp.sum(weights, axis=-1, keepdims=True), 1e-9)
    return weights, experts


def _capacity(n_tokens, moe):
    c = int(n_tokens * moe.top_k / moe.num_experts * moe.capacity_factor)
    return max(8, -(-c // 8) * 8)  # round up to 8


def _dispatch_compute_combine(p, x2d, weights, experts, capacity, moe):
    """Sort-based dispatch -> grouped expert SwiGLU -> weighted combine.

    x2d (T,d); weights/experts (T,k). Returns (T,d).
    """
    t, d = x2d.shape
    e, k = moe.num_experts, moe.top_k
    n = t * k
    flat_e = experts.reshape(n)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    counts = jnp.bincount(flat_e, length=e)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(n) - starts[sorted_e]           # slot within expert block
    keep = pos < capacity
    dest_c = jnp.where(keep, pos, capacity)          # overflow -> col `capacity`
    tok = order // k

    slot_tok = jnp.full((e, capacity + 1), t, jnp.int32)
    slot_tok = slot_tok.at[sorted_e, dest_c].set(tok, mode="drop")
    slot_w = jnp.zeros((e, capacity + 1), weights.dtype)
    slot_w = slot_w.at[sorted_e, dest_c].set(weights.reshape(n)[order],
                                             mode="drop")
    slot_tok, slot_w = slot_tok[:, :capacity], slot_w[:, :capacity]

    x_pad = jnp.concatenate([x2d, jnp.zeros((1, d), x2d.dtype)], axis=0)
    xs = x_pad[slot_tok]                             # (E, C, d)

    def _w(q):
        return q["w"].value if cm.is_param(q["w"]) else q["w"]
    wg = _w(p["gate"]).astype(xs.dtype)
    wu = _w(p["up"]).astype(xs.dtype)
    wd = _w(p["down"]).astype(xs.dtype)
    if wg.shape[0] < e:  # shard_map local path: drop the phantom expert row
        xs, slot_tok, slot_w = (a[:wg.shape[0]] for a in (xs, slot_tok, slot_w))
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xs, wg)) * jnp.einsum(
        "ecd,edf->ecf", xs, wu)
    out = jnp.einsum("ecf,efd->ecd", h, wd)          # (E, C, d)

    out = out * slot_w[..., None].astype(out.dtype)
    y = jnp.zeros((t + 1, d), out.dtype).at[slot_tok.reshape(-1)].add(
        out.reshape(-1, d), mode="drop")
    return y[:t]


def moe_forward_gather(p, x, cfg):
    """Baseline pjit-global MoE. x: (B, S, d)."""
    moe = cfg.moe
    b, s, d = x.shape
    x2d = x.reshape(b * s, d)
    weights, experts = _route(p["router"], x2d, moe)
    cap = _capacity(b * s, moe)
    y = _dispatch_compute_combine(p, x2d, weights, experts, cap, moe)
    if "shared" in p:
        y = y + swiglu(p["shared"], x2d)
    return y.reshape(b, s, d)


def moe_forward_shardmap(p, x, cfg, mesh, *, dp_axes=("data",),
                         ep_axis="model"):
    """Optimized MoE: local dispatch per (data, model) block + psum combine.

    Token activations are replicated over `model`; expert weights are sharded
    over `model`. Each model-rank routes its (replicated) token block against
    the full router, dispatches only the tokens destined for ITS experts, and
    contributes a partial output; a single psum over `model` combines.
    """
    moe = cfg.moe
    e_total = moe.num_experts
    ep = mesh.shape[ep_axis]
    e_local = e_total // ep
    assert e_local * ep == e_total, (e_total, ep)

    def local_fn(x_blk, router_w, wg, wu, wd, shared):
        b, s, d = x_blk.shape
        x2d = x_blk.reshape(b * s, d)
        weights, experts = _route({"w": router_w}, x2d, moe)
        my = jax.lax.axis_index(ep_axis)
        lo = my * e_local
        # keep only (token, k) choices routed to my expert shard
        mine = (experts >= lo) & (experts < lo + e_local)
        local_experts = jnp.where(mine, experts - lo, e_local)  # e_local = drop
        local_weights = jnp.where(mine, weights, 0.0)
        cap = max(8, _capacity(b * s, moe) // ep * 2)  # local capacity w/ slack
        lp = {"gate": {"w": wg}, "up": {"w": wu}, "down": {"w": wd}}
        lmoe = _LocalMoE(e_local, moe.top_k)
        y = _dispatch_compute_combine(lp, x2d, local_weights, local_experts,
                                      cap, lmoe)
        y = jax.lax.psum(y, ep_axis)
        if shared is not None:
            y = y + swiglu(shared, x2d)
        return y.reshape(b, s, d)

    x_spec = P(dp_axes, None, None)
    shared = p.get("shared")
    from repro.kernels import compat
    fn = compat.shard_map(
        local_fn, mesh,
        in_specs=(x_spec, P(None, None), P(ep_axis, None, None),
                  P(ep_axis, None, None), P(ep_axis, None, None),
                  None if shared is None else jax.tree.map(
                      lambda _: P(None, None), cm.values(shared))),
        out_specs=x_spec, check_vma=False)
    return fn(x, p["router"]["w"].value,
              p["gate"]["w"].value, p["up"]["w"].value, p["down"]["w"].value,
              None if shared is None else cm.values(shared))


class _LocalMoE:
    """Duck-typed stand-in for MoEConfig inside the shard_map local block:
    one extra phantom expert id (= e_local) absorbs dropped tokens."""
    def __init__(self, e_local, top_k):
        self.num_experts = e_local + 1
        self.top_k = top_k
