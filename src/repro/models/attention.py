"""Attention mixers: GQA (covers MHA/MQA), MLA (latent attention), plus the
chunked XLA attention used for training/prefill (flash-style memory behaviour
without Pallas — the Pallas kernel in ``repro.kernels`` is the TPU fast path;
``repro.kernels.ops`` dispatches between them)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common as cm

NEG_INF = -1e30


def _constrain(x, axes):
    from repro.distributed import sharding as shd
    return shd.constrain(x, axes)


def _constrain_if(x, axes, key):
    from repro.distributed import sharding as shd
    return shd.constrain_if(x, axes, key)


# ---------------------------------------------------------------------------
# Chunked attention (XLA path): scan over query chunks; never materializes SxS
# ---------------------------------------------------------------------------

def chunked_attention(q, k, v, *, causal=True, window=0, q_offset=0,
                      q_chunk=1024, logits_dtype=jnp.float32):
    """q: (B, Sq, Hq, D); k/v: (B, Sk, Hkv, D). GQA via head grouping.

    window > 0 means sliding-window causal attention (each query attends the
    previous `window` keys). q_offset: absolute position of q[0] relative to
    k[0] (for prefill continuation). Returns (B, Sq, Hq, D).
    """
    b, sq, hq, d = q.shape
    _, sk, hkv, _ = k.shape
    dv = v.shape[-1]  # may differ from d (e.g. MLA)
    groups = hq // hkv
    scale = d ** -0.5
    q = q * scale
    # reshape q to (B, Sq, Hkv, G, D) so contraction maps onto kv heads
    qg = q.reshape(b, sq, hkv, groups, d)

    q_chunk = min(q_chunk, sq)
    while sq % q_chunk:
        q_chunk //= 2
    n_chunks = sq // q_chunk

    k_pos = jnp.arange(sk)
    # STRIDED chunking: row r of chunk ci sits at global position
    # ci + r*n_chunks. Under sequence-parallel sharding a contiguous chunk
    # lives entirely inside ONE seq shard, so GSPMD all-gathers the whole
    # q tensor per layer to redistribute it (measured 1 GiB/layer on
    # codeqwen prefill — §Perf). A strided chunk takes q_chunk/16 rows
    # from EVERY shard: the slice is already evenly sharded and no q/o
    # gathers are needed. Compute and masking are position-parametric, so
    # the result is identical for any chunk->position mapping.
    qg5 = qg.reshape(b, q_chunk, n_chunks, hkv, groups, d)

    def one_chunk(ci):
        qs = qg5[:, :, ci]
        # pin the einsum INPUT shardings: q-chunk carries the model axis
        # when heads don't divide it ("attn_q" rule), K/V replicated —
        # otherwise GSPMD picks a head-dim sharding for q and pays an
        # involuntary remat + per-chunk gathers. Only applied when attn_q
        # is mapped (unconditional pinning regressed divisible-head archs
        # 16-18% — §Perf train iteration).
        qs = _constrain_if(qs, ("batch", "attn_q", None, None, "head_dim"),
                           "attn_q")
        q_pos = q_offset + ci + jnp.arange(q_chunk) * n_chunks
        # scores: (B, Hkv, G, Qc, Sk)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qs, k,
                       preferred_element_type=logits_dtype)
        s = _constrain(s, ("batch", "kv_heads", None, "attn_q", None))
        mask = jnp.ones((q_chunk, sk), bool)
        if causal:
            mask &= q_pos[:, None] >= k_pos[None, :]
        if isinstance(window, jnp.ndarray):  # traced per-layer window (0=full)
            mask &= (window <= 0) | (q_pos[:, None] - k_pos[None, :] < window)
        elif window:
            mask &= q_pos[:, None] - k_pos[None, :] < window
        s = jnp.where(mask, s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
        p = _constrain(p, ("batch", "kv_heads", None, "attn_q", None))
        o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v)
        return _constrain(o.reshape(b, q_chunk, hq, dv),
                          ("batch", "attn_q", "heads", "head_dim"))

    if n_chunks == 1:
        return one_chunk(0)
    outs = jax.lax.map(one_chunk, jnp.arange(n_chunks))   # (N, B, Qc, Hq, Dv)
    # inverse of the strided mapping: position = r*n_chunks + ci, so
    # (qc-major, nc-minor) reshape restores sequence order
    return jnp.moveaxis(outs, 0, 2).reshape(b, sq, hq, dv)


def decode_attention(q, k_cache, v_cache, cache_len, *, window=0):
    """Single-position attention. q: (B, 1, Hq, D); caches (B, S, Hkv, D);
    cache_len: (B,) or scalar number of valid cache entries (q's position ==
    cache_len - 1 after the new KV was written)."""
    b, _, hq, d = q.shape
    _, s, hkv, _ = k_cache.shape
    groups = hq // hkv
    qg = (q * d ** -0.5).reshape(b, hkv, groups, d)
    s_logits = jnp.einsum("bhgd,bkhd->bhgk", qg, k_cache,
                          preferred_element_type=jnp.float32)
    k_pos = jnp.arange(s)
    valid = k_pos[None, :] < jnp.reshape(cache_len, (-1, 1))
    if isinstance(window, jnp.ndarray):
        valid &= (window <= 0) | (
            k_pos[None, :] >= jnp.reshape(cache_len, (-1, 1)) - window)
    elif window:
        valid &= k_pos[None, :] >= jnp.reshape(cache_len, (-1, 1)) - window
    s_logits = jnp.where(valid[:, None, None, :], s_logits, NEG_INF)
    p = jax.nn.softmax(s_logits, axis=-1).astype(v_cache.dtype)
    o = jnp.einsum("bhgk,bkhd->bhgd", p, v_cache)
    return o.reshape(b, 1, hq, d)


# ---------------------------------------------------------------------------
# GQA block
# ---------------------------------------------------------------------------

def gqa_init(key, cfg):
    ks = jax.random.split(key, 4)
    h, kv, d, hd = cfg.n_heads, cfg.n_kv_heads, cfg.d_model, cfg.head_dim
    return {
        "q": cm.dense(ks[0], d, (h, hd), ("embed", "heads", "head_dim"),
                      bias=cfg.qkv_bias),
        "k": cm.dense(ks[1], d, (kv, hd), ("embed", "kv_heads", "head_dim"),
                      bias=cfg.qkv_bias),
        "v": cm.dense(ks[2], d, (kv, hd), ("embed", "kv_heads", "head_dim"),
                      bias=cfg.qkv_bias),
        "o": cm.dense(ks[3], (h, hd), d, ("heads", "head_dim", "embed")),
    }


def gqa_project_qkv(p, x, positions, theta):
    q = cm.apply_dense(p["q"], x)            # (B,S,H,hd)
    k = cm.apply_dense(p["k"], x)            # (B,S,KV,hd)
    v = cm.apply_dense(p["v"], x)
    q = _constrain(cm.apply_rope(q, positions, theta),
                   ("batch", "seq", "heads", "head_dim"))
    k = _constrain(cm.apply_rope(k, positions, theta),
                   ("batch", "seq", "kv_heads", "head_dim"))
    v = _constrain(v, ("batch", "seq", "kv_heads", "head_dim"))
    return q, k, v


def gqa_forward(p, x, cfg, *, positions, window=0, causal=True):
    q, k, v = gqa_project_qkv(p, x, positions, cfg.rope_theta)
    o = chunked_attention(q, k, v, causal=causal, window=window)
    return cm.apply_dense(p["o"], o, in_dims=2)


def write_kv(cache, new, pos):
    """Insert one position per sequence into a (B, S, ...) cache.

    pos scalar: dynamic_update_slice (single shared position — the dry-run /
    synchronous-batch path). pos (B,): per-slot one-hot blend (continuous
    batching: every slot is at its own depth). The one-hot write streams the
    cache once — the same traffic decode attention already pays."""
    if getattr(pos, "ndim", 0) == 0 and not isinstance(pos, (list, tuple)):
        idx = (0,) * 1 + (pos,) + (0,) * (cache.ndim - 2)
        return jax.lax.dynamic_update_slice(
            cache, new.astype(cache.dtype), idx)
    s = cache.shape[1]
    onehot = (jnp.arange(s)[None, :] == pos[:, None])
    onehot = onehot.reshape(onehot.shape + (1,) * (cache.ndim - 2))
    return jnp.where(onehot, new.astype(cache.dtype), cache)


def gqa_decode(p, x, cache_k, cache_v, pos, cfg, *, window=0):
    """x: (B,1,d); caches (B,S,KV,hd); pos: scalar or (B,) write index."""
    q = cm.apply_dense(p["q"], x)
    k = cm.apply_dense(p["k"], x)
    v = cm.apply_dense(p["v"], x)
    positions = (jnp.full((x.shape[0], 1), pos)
                 if getattr(pos, "ndim", 0) == 0 else pos[:, None])
    q = cm.apply_rope(q, positions, cfg.rope_theta)
    k = cm.apply_rope(k, positions, cfg.rope_theta)
    cache_k = write_kv(cache_k, k, pos)
    cache_v = write_kv(cache_v, v, pos)
    o = decode_attention(q, cache_k, cache_v, pos + 1, window=window)
    return cm.apply_dense(p["o"], o, in_dims=2), cache_k, cache_v


# ---------------------------------------------------------------------------
# int8 KV cache (decode is memory-bound on the cache stream; int8 + a
# per-(position, head) scale halves the bytes — §Perf pair C)
# ---------------------------------------------------------------------------

def quant_kv(x):
    """x: (B, 1, KV, D) -> (int8 values, bf16 scales (B, 1, KV))."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    q = jnp.round(x.astype(jnp.float32)
                  / jnp.maximum(scale[..., None], 1e-8))
    return jnp.clip(q, -127, 127).astype(jnp.int8), \
        scale.astype(jnp.bfloat16)


def dequant_kv(cache, scale, dtype):
    """(B, S, KV, D) int8 x (B, S, KV) -> dtype. The convert+scale fuses
    into the attention dot's operand read: HBM streams int8."""
    return cache.astype(dtype) * scale[..., None].astype(dtype)


def gqa_decode_q8(p, x, cache_k, cache_v, k_scale, v_scale, pos, cfg, *,
                  window=0):
    """gqa_decode against an int8-quantized KV cache."""
    q = cm.apply_dense(p["q"], x)
    k = cm.apply_dense(p["k"], x)
    v = cm.apply_dense(p["v"], x)
    positions = (jnp.full((x.shape[0], 1), pos)
                 if getattr(pos, "ndim", 0) == 0 else pos[:, None])
    q = cm.apply_rope(q, positions, cfg.rope_theta)
    k = cm.apply_rope(k, positions, cfg.rope_theta)
    kq, ks = quant_kv(k)
    vq, vs = quant_kv(v)
    cache_k = write_kv(cache_k, kq, pos)
    cache_v = write_kv(cache_v, vq, pos)
    k_scale = write_kv(k_scale, ks, pos)
    v_scale = write_kv(v_scale, vs, pos)
    kf = dequant_kv(cache_k, k_scale, x.dtype)
    vf = dequant_kv(cache_v, v_scale, x.dtype)
    o = decode_attention(q, kf, vf, pos + 1, window=window)
    return (cm.apply_dense(p["o"], o, in_dims=2), cache_k, cache_v,
            k_scale, v_scale)


# ---------------------------------------------------------------------------
# MLA (multi-head latent attention) — DeepSeek-V2 / MiniCPM3 style
# ---------------------------------------------------------------------------

def mla_init(key, cfg):
    m = cfg.mla
    ks = jax.random.split(key, 7)
    d, h = cfg.d_model, cfg.n_heads
    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "q_down": cm.dense(ks[0], d, m.q_lora_rank, ("embed", "q_lora")),
        "q_up": cm.dense(ks[1], m.q_lora_rank, (h, qk_head),
                         ("q_lora", "heads", "head_dim")),
        "kv_down": cm.dense(ks[2], d, m.kv_lora_rank, ("embed", "kv_lora")),
        "k_rope": cm.dense(ks[3], d, (1, m.qk_rope_head_dim),
                           ("embed", "kv_heads", "head_dim")),
        "k_up": cm.dense(ks[4], m.kv_lora_rank, (h, m.qk_nope_head_dim),
                         ("kv_lora", "heads", "head_dim")),
        "v_up": cm.dense(ks[5], m.kv_lora_rank, (h, m.v_head_dim),
                         ("kv_lora", "heads", "head_dim")),
        "o": cm.dense(ks[6], (h, m.v_head_dim), d,
                      ("heads", "head_dim", "embed")),
    }


def mla_forward(p, x, cfg, *, positions):
    """Training/prefill path: expand the latent to per-head K/V."""
    m = cfg.mla
    q = cm.apply_dense(p["q_up"], cm.apply_dense(p["q_down"], x))  # (B,S,H,qk)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = cm.apply_rope(q_rope, positions, cfg.rope_theta)

    c_kv = cm.apply_dense(p["kv_down"], x)                         # (B,S,r)
    k_rope = cm.apply_dense(p["k_rope"], x)                        # (B,S,1,rd)
    k_rope = cm.apply_rope(k_rope, positions, cfg.rope_theta)
    k_nope = cm.apply_dense(p["k_up"], c_kv)                       # (B,S,H,nd)
    v = cm.apply_dense(p["v_up"], c_kv)                            # (B,S,H,vd)

    k_rope_b = jnp.broadcast_to(k_rope, k_rope.shape[:2] + (cfg.n_heads,
                                                            m.qk_rope_head_dim))
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_full = jnp.concatenate([k_nope, k_rope_b], axis=-1)
    o = chunked_attention(q_full, k_full, v, causal=True)
    return cm.apply_dense(p["o"], o, in_dims=2)


def mla_decode(p, x, cache_ckv, cache_krope, pos, cfg):
    """Absorbed decode: score against the cached latent directly.

    cache_ckv: (B, S, r);  cache_krope: (B, S, rd); pos scalar or (B,).
    q_latent[h] = W_uk[h]^T q_nope[h]  ->  score = q_latent . c_kv + q_rope . k_rope
    output o[h] = (attn . c_kv) @ W_uv[h]  (v absorbed after the fact).
    """
    m = cfg.mla
    b = x.shape[0]
    q = cm.apply_dense(p["q_up"], cm.apply_dense(p["q_down"], x))  # (B,1,H,qk)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    positions = (jnp.full((b, 1), pos) if getattr(pos, "ndim", 0) == 0
                 else pos[:, None])
    q_rope = cm.apply_rope(q_rope, positions, cfg.rope_theta)

    c_kv = cm.apply_dense(p["kv_down"], x)                         # (B,1,r)
    k_rope = cm.apply_dense(p["k_rope"], x)[:, :, 0]               # (B,1,rd)
    k_rope = cm.apply_rope(k_rope[:, :, None], positions, cfg.rope_theta)[:, :, 0]
    cache_ckv = write_kv(cache_ckv, c_kv, pos)
    cache_krope = write_kv(cache_krope, k_rope, pos)

    w_uk = p["k_up"]["w"].value.astype(x.dtype)                    # (r,H,nd)
    q_lat = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0], w_uk)         # (B,H,r)
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    s_lat = jnp.einsum("bhr,bsr->bhs", q_lat, cache_ckv,
                       preferred_element_type=jnp.float32)
    s_rope = jnp.einsum("bhd,bsd->bhs", q_rope[:, 0], cache_krope,
                        preferred_element_type=jnp.float32)
    s = (s_lat + s_rope) * scale
    valid = (jnp.arange(cache_ckv.shape[1])[None, :]
             <= jnp.reshape(pos, (-1, 1)))
    s = jnp.where(valid[:, None, :], s, NEG_INF)
    pattn = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bhs,bsr->bhr", pattn.astype(cache_ckv.dtype), cache_ckv)
    w_uv = p["v_up"]["w"].value.astype(x.dtype)                    # (r,H,vd)
    o = jnp.einsum("bhr,rhd->bhd", ctx, w_uv)[:, None]             # (B,1,H,vd)
    return cm.apply_dense(p["o"], o, in_dims=2), cache_ckv, cache_krope
