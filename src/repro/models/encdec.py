"""Encoder-decoder LM (SeamlessM4T backbone). The audio frontend is a stub:
the encoder consumes precomputed frame embeddings (B, S_enc, d_model)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import common as cm
from repro.models import ffn as ffn_mod


def _enc_block_init(key, cfg):
    ks = jax.random.split(key, 2)
    return {
        "attn_norm": cm.rmsnorm_init(cfg.d_model),
        "attn": attn.gqa_init(ks[0], cfg),
        "ffn_norm": cm.rmsnorm_init(cfg.d_model),
        "ffn": ffn_mod.swiglu_init(ks[1], cfg.d_model, cfg.d_ff),
    }


def _dec_block_init(key, cfg):
    ks = jax.random.split(key, 3)
    return {
        "self_norm": cm.rmsnorm_init(cfg.d_model),
        "self_attn": attn.gqa_init(ks[0], cfg),
        "cross_norm": cm.rmsnorm_init(cfg.d_model),
        "cross_attn": attn.gqa_init(ks[1], cfg),
        "ffn_norm": cm.rmsnorm_init(cfg.d_model),
        "ffn": ffn_mod.swiglu_init(ks[2], cfg.d_model, cfg.d_ff),
    }


def init(key, cfg):
    ks = jax.random.split(key, 5)
    return {
        "embed": cm.embedding(ks[0], cfg.vocab_size, cfg.d_model),
        "enc_in_proj": cm.dense(ks[1], cfg.d_model, cfg.d_model,
                                ("embed", "embed2")),
        "enc_layers": cm.stack_layers(lambda k: _enc_block_init(k, cfg),
                                      ks[2], cfg.n_encoder_layers),
        "enc_norm": cm.rmsnorm_init(cfg.d_model),
        "dec_layers": cm.stack_layers(lambda k: _dec_block_init(k, cfg),
                                      ks[3], cfg.n_layers),
        "final_norm": cm.rmsnorm_init(cfg.d_model),
        "unembed": cm.dense(ks[4], cfg.d_model, cfg.vocab_size,
                            ("embed", "vocab")),
    }


def encode(params, cfg, enc_embeds, *, dtype=jnp.bfloat16):
    x = cm.apply_dense(params["enc_in_proj"], enc_embeds.astype(dtype))
    positions = jnp.arange(x.shape[1])[None, :]

    def body(x, lp):
        h = cm.rmsnorm(lp["attn_norm"], x, cfg.rms_eps)
        a = attn.gqa_forward(lp["attn"], h, cfg, positions=positions,
                             causal=False)
        x = x + a
        h = cm.rmsnorm(lp["ffn_norm"], x, cfg.rms_eps)
        return x + ffn_mod.swiglu(lp["ffn"], h), None

    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return cm.rmsnorm(params["enc_norm"], x, cfg.rms_eps)


def _cross_attend(lp, h, enc_k, enc_v):
    q = cm.apply_dense(lp["q"], h)   # no rope on cross-attention
    o = attn.chunked_attention(q, enc_k, enc_v, causal=False)
    return cm.apply_dense(lp["o"], o, in_dims=2)


def forward(params, cfg, tokens, enc_embeds, *, dtype=jnp.bfloat16,
            remat=False):
    """Training path. tokens: (B, S_dec); enc_embeds: (B, S_enc, d)."""
    enc_out = encode(params, cfg, enc_embeds, dtype=dtype)
    emb = params["embed"]["embedding"].value
    x = jnp.take(emb, tokens, axis=0).astype(dtype)
    positions = jnp.arange(x.shape[1])[None, :]

    def body(x, lp):
        h = cm.rmsnorm(lp["self_norm"], x, cfg.rms_eps)
        x = x + attn.gqa_forward(lp["self_attn"], h, cfg,
                                 positions=positions)
        h = cm.rmsnorm(lp["cross_norm"], x, cfg.rms_eps)
        ek = cm.apply_dense(lp["cross_attn"]["k"], enc_out)
        ev = cm.apply_dense(lp["cross_attn"]["v"], enc_out)
        x = x + _cross_attend(lp["cross_attn"], h, ek, ev)
        h = cm.rmsnorm(lp["ffn_norm"], x, cfg.rms_eps)
        return x + ffn_mod.swiglu(lp["ffn"], h), None

    if remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    x = cm.rmsnorm(params["final_norm"], x, cfg.rms_eps)
    return cm.apply_dense(params["unembed"], x).astype(jnp.float32)


def loss_fn(params, cfg, batch, *, dtype=jnp.bfloat16, remat=True,
            moe_ctx=None):
    tokens = batch["tokens"]
    logits = forward(params, cfg, tokens, batch["enc_embeds"], dtype=dtype,
                     remat=remat)
    labels = jnp.concatenate(
        [tokens[:, 1:], jnp.zeros_like(tokens[:, :1])], axis=1)
    mask = jnp.concatenate(
        [jnp.ones_like(tokens[:, 1:], jnp.float32),
         jnp.zeros_like(tokens[:, :1], jnp.float32)], axis=1)
    return cm.softmax_cross_entropy(logits, labels, mask)


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

def init_cache(cfg, batch: int, max_len: int, enc_len: int,
               dtype=jnp.bfloat16):
    L, kv, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
    self_axes = ("layer", "batch", "kv_seq", "kv_heads", "head_dim")
    cross_axes = ("layer", "batch", "enc_seq", "kv_heads", "head_dim")
    return {
        "k": cm.Param(jnp.zeros((L, batch, max_len, kv, hd), dtype), self_axes),
        "v": cm.Param(jnp.zeros((L, batch, max_len, kv, hd), dtype), self_axes),
        "ek": cm.Param(jnp.zeros((L, batch, enc_len, kv, hd), dtype), cross_axes),
        "ev": cm.Param(jnp.zeros((L, batch, enc_len, kv, hd), dtype), cross_axes),
        "pos": cm.Param(jnp.zeros((), jnp.int32), ()),
    }


def prefill(params, cfg, tokens, enc_embeds, *, max_len=None,
            dtype=jnp.bfloat16):
    """Encode + run decoder over `tokens`, capturing self and cross KV."""
    enc_out = encode(params, cfg, enc_embeds, dtype=dtype)
    emb = params["embed"]["embedding"].value
    x = jnp.take(emb, tokens, axis=0).astype(dtype)
    b, seq = tokens.shape
    max_len = max_len or seq
    positions = jnp.arange(seq)[None, :]

    def body(x, lp):
        h = cm.rmsnorm(lp["self_norm"], x, cfg.rms_eps)
        q, k, v = attn.gqa_project_qkv(lp["self_attn"], h, positions,
                                       cfg.rope_theta)
        o = attn.chunked_attention(q, k, v, causal=True)
        x = x + cm.apply_dense(lp["self_attn"]["o"], o, in_dims=2)
        h = cm.rmsnorm(lp["cross_norm"], x, cfg.rms_eps)
        ek = cm.apply_dense(lp["cross_attn"]["k"], enc_out)
        ev = cm.apply_dense(lp["cross_attn"]["v"], enc_out)
        x = x + _cross_attend(lp["cross_attn"], h, ek, ev)
        h = cm.rmsnorm(lp["ffn_norm"], x, cfg.rms_eps)
        x = x + ffn_mod.swiglu(lp["ffn"], h)
        caches = {
            "k": _pad_to(k, max_len).astype(dtype),
            "v": _pad_to(v, max_len).astype(dtype),
            "ek": ek.astype(dtype), "ev": ev.astype(dtype),
        }
        return x, caches

    x, cache_stk = jax.lax.scan(body, x, params["dec_layers"])
    x = cm.rmsnorm(params["final_norm"], x, cfg.rms_eps)
    logits = cm.apply_dense(params["unembed"], x[:, -1:]).astype(jnp.float32)
    axes = {
        "k": ("layer", "batch", "kv_seq", "kv_heads", "head_dim"),
        "v": ("layer", "batch", "kv_seq", "kv_heads", "head_dim"),
        "ek": ("layer", "batch", "enc_seq", "kv_heads", "head_dim"),
        "ev": ("layer", "batch", "enc_seq", "kv_heads", "head_dim"),
    }
    cache = {k: cm.Param(v, axes[k]) for k, v in cache_stk.items()}
    cache["pos"] = cm.Param(jnp.asarray(min(seq, max_len), jnp.int32), ())
    return logits, cache


def decode_step(params, cfg, cache, token, *, dtype=jnp.bfloat16):
    pos = cache["pos"].value
    emb = params["embed"]["embedding"].value
    x = jnp.take(emb, token, axis=0).astype(dtype)
    cache_vals = {k: v.value for k, v in cache.items() if k != "pos"}

    def body(x, layer_in):
        lp, cl = layer_in
        h = cm.rmsnorm(lp["self_norm"], x, cfg.rms_eps)
        a, ck, cv = attn.gqa_decode(lp["self_attn"], h, cl["k"], cl["v"],
                                    pos, cfg)
        x = x + a
        h = cm.rmsnorm(lp["cross_norm"], x, cfg.rms_eps)
        q = cm.apply_dense(lp["cross_attn"]["q"], h)
        o = attn.decode_attention(q, cl["ek"], cl["ev"], cl["ek"].shape[1])
        x = x + cm.apply_dense(lp["cross_attn"]["o"], o, in_dims=2)
        h = cm.rmsnorm(lp["ffn_norm"], x, cfg.rms_eps)
        x = x + ffn_mod.swiglu(lp["ffn"], h)
        return x, {"k": ck, "v": cv, "ek": cl["ek"], "ev": cl["ev"]}

    x, new_vals = jax.lax.scan(body, x, (params["dec_layers"], cache_vals))
    x = cm.rmsnorm(params["final_norm"], x, cfg.rms_eps)
    logits = cm.apply_dense(params["unembed"], x).astype(jnp.float32)
    new_cache = {k: cm.Param(v, cache[k].axes) for k, v in new_vals.items()}
    new_cache["pos"] = cm.Param(pos + 1, ())
    return logits, new_cache


def _pad_to(x, n):
    if x.shape[1] == n:
        return x
    pad = [(0, 0)] * x.ndim
    pad[1] = (0, n - x.shape[1])
    return jnp.pad(x, pad)
