"""Mamba2 SSD (state-space duality) mixer. [arXiv:2405.21060]

Train/prefill use the chunked dual form (quadratic within a chunk, linear
recurrence across chunks) — implemented here in pure jnp with a lax.scan over
chunks; ``repro.kernels.ssd_scan`` is the Pallas TPU version of the same
schedule and ``repro.kernels.ref.ssd_ref`` is the naive-recurrence oracle both
are tested against. Decode is a single recurrent state update.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common as cm


def dims(cfg):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    conv_ch = d_inner + 2 * s.n_groups * s.d_state
    return d_inner, n_heads, conv_ch


def mamba2_init(key, cfg):
    s = cfg.ssm
    d_inner, nh, conv_ch = dims(cfg)
    ks = jax.random.split(key, 6)
    p = {
        "z_proj": cm.dense(ks[0], cfg.d_model, d_inner, ("embed", "ssm_inner")),
        "xbc_proj": cm.dense(ks[1], cfg.d_model, conv_ch, ("embed", "ssm_conv_ch")),
        "dt_proj": cm.dense(ks[2], cfg.d_model, nh, ("embed", "ssm_heads")),
        "out_proj": cm.dense(ks[3], d_inner, cfg.d_model, ("ssm_inner", "embed")),
        "conv_w": cm.Param(
            jax.random.normal(ks[4], (s.conv_width, conv_ch)) * 0.1,
            ("conv", "ssm_conv_ch")),
        "dt_bias": cm.Param(jnp.zeros((nh,)), ("ssm_heads",)),
        "A_log": cm.Param(jnp.log(jnp.arange(1, nh + 1, dtype=jnp.float32)),
                          ("ssm_heads",)),
        "D": cm.Param(jnp.ones((nh,)), ("ssm_heads",)),
        "norm": cm.rmsnorm_init(d_inner, "ssm_inner"),
    }
    return p


def _causal_conv(x, w):
    """Depthwise causal conv. x: (B, S, C); w: (W, C)."""
    wdt = w.astype(x.dtype)
    width = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(width):
        out = out + pad[:, i:i + x.shape[1]] * wdt[i]
    return out


def ssd_chunked(dx, dA, B, C, chunk, initial_state=None):
    """Chunked SSD. All fp32 math on the state path.

    dx: (B, S, H, P) inputs pre-multiplied by dt
    dA: (B, S, H)    per-step log-decay (dt * A, negative)
    B, C: (B, S, G, N) input/output projections (G groups broadcast to H)
    Returns y (B, S, H, P), final_state (B, H, N, P).
    """
    b, s, h, p = dx.shape
    g, n = B.shape[2], B.shape[3]
    hg = h // g
    nc = s // chunk
    f32 = jnp.float32

    dxc = dx.reshape(b, nc, chunk, h, p)
    dAc = dA.reshape(b, nc, chunk, h).astype(f32)
    Bc = B.reshape(b, nc, chunk, g, n)
    Cc = C.reshape(b, nc, chunk, g, n)

    if initial_state is None:
        initial_state = jnp.zeros((b, h, n, p), f32)

    def step(state, inp):
        dx_i, dA_i, B_i, C_i = inp          # (b,chunk,...)
        cs = jnp.cumsum(dA_i, axis=1)       # (b,L,h) inclusive
        # intra-chunk scores: (b, L, L, g)
        scores = jnp.einsum("blgn,bsgn->blsg", Cc_ast(C_i), Cc_ast(B_i))
        # decay factor exp(cs_l - cs_s) for l >= s  -> (b, L, L, h).
        # mask BEFORE exp: the upper triangle has delta >> 0 whose exp
        # overflows to inf and poisons gradients through the where.
        delta = cs[:, :, None, :] - cs[:, None, :, :]
        causal = jnp.tril(jnp.ones((chunk, chunk), bool))
        decay = jnp.exp(jnp.where(causal[None, :, :, None], delta, -1e30))
        scores_h = jnp.repeat(scores, hg, axis=-1) if g > 1 else (
            jnp.broadcast_to(scores, scores.shape[:3] + (h,)))
        m = scores_h * decay                # (b, L, L, h)
        y_diag = jnp.einsum("blsh,bshp->blhp", m, dx_i.astype(f32))
        # contribution of incoming state: decay from chunk start
        dec0 = jnp.exp(cs)                  # (b, L, h)
        C_h = _group_to_heads(C_i, h)       # (b, L, h, n)
        y_off = jnp.einsum("blhn,bhnp->blhp", C_h * dec0[..., None], state)
        # new state: state * total-decay + sum_s B_s x_s decayed to chunk end
        dec_end = jnp.exp(cs[:, -1:, :] - cs)          # (b, L, h)
        B_h = _group_to_heads(B_i, h)
        state_new = jnp.einsum("blhn,blhp->bhnp",
                               B_h * dec_end[..., None], dx_i.astype(f32))
        state = state * jnp.exp(cs[:, -1])[:, :, None, None] + state_new
        return state, (y_diag + y_off)

    def Cc_ast(x):
        return x.astype(f32)

    xs = (jnp.moveaxis(dxc, 1, 0), jnp.moveaxis(dAc, 1, 0),
          jnp.moveaxis(Bc, 1, 0), jnp.moveaxis(Cc, 1, 0))
    final_state, ys = jax.lax.scan(step, initial_state, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, h, p)
    return y.astype(dx.dtype), final_state


def _group_to_heads(x, h):
    """(b, L, g, n) -> (b, L, h, n) by repeating each group h//g times."""
    b, l, g, n = x.shape
    if g == h:
        return x.astype(jnp.float32)
    return jnp.repeat(x.astype(jnp.float32), h // g, axis=2)


def mamba2_forward(p, x, cfg, *, return_state=False, initial_state=None,
                   conv_init=None):
    """x: (B, S, d_model) -> (B, S, d_model) [+ (ssm_state, conv_buffer)]."""
    s = cfg.ssm
    d_inner, nh, conv_ch = dims(cfg)
    b, seq, _ = x.shape

    z = cm.apply_dense(p["z_proj"], x)                       # (B,S,di)
    xbc = cm.apply_dense(p["xbc_proj"], x)                   # (B,S,cc)
    if conv_init is not None:
        xbc_ext = jnp.concatenate([conv_init.astype(xbc.dtype), xbc], axis=1)
        conv = _causal_conv(xbc_ext, p["conv_w"].value)[:, conv_init.shape[1]:]
    else:
        conv = _causal_conv(xbc, p["conv_w"].value)
    conv = jax.nn.silu(conv)
    xin = conv[..., :d_inner]
    Bmat = conv[..., d_inner:d_inner + s.n_groups * s.d_state]
    Cmat = conv[..., d_inner + s.n_groups * s.d_state:]
    Bmat = Bmat.reshape(b, seq, s.n_groups, s.d_state)
    Cmat = Cmat.reshape(b, seq, s.n_groups, s.d_state)

    dt = jax.nn.softplus(
        cm.apply_dense(p["dt_proj"], x).astype(jnp.float32)
        + p["dt_bias"].value)                                # (B,S,H)
    A = -jnp.exp(p["A_log"].value)                           # (H,)
    dA = dt * A                                              # log decay
    xh = xin.reshape(b, seq, nh, s.head_dim)
    dx = xh * dt[..., None].astype(xh.dtype)

    chunk = min(s.chunk_size, seq)
    while seq % chunk:
        chunk //= 2
    y, state = ssd_chunked(dx, dA, Bmat, Cmat, chunk,
                           initial_state=initial_state)
    y = y + xh * p["D"].value[None, None, :, None].astype(y.dtype)
    y = y.reshape(b, seq, d_inner)
    y = cm.rmsnorm(p["norm"], y * jax.nn.silu(z), cfg.rms_eps)
    out = cm.apply_dense(p["out_proj"], y)
    if return_state:
        width = s.conv_width
        conv_buf = xbc[:, -(width - 1):] if seq >= width - 1 else jnp.pad(
            xbc, ((0, 0), (width - 1 - seq, 0), (0, 0)))
        return out, (state, conv_buf)
    return out


def mamba2_decode(p, x, state, conv_buf, cfg):
    """One-token step. x: (B, 1, d_model); state (B,H,N,P) fp32;
    conv_buf (B, W-1, conv_ch). Returns (y, state, conv_buf)."""
    s = cfg.ssm
    d_inner, nh, conv_ch = dims(cfg)
    b = x.shape[0]

    z = cm.apply_dense(p["z_proj"], x)[:, 0]                 # (B,di)
    xbc = cm.apply_dense(p["xbc_proj"], x)[:, 0]             # (B,cc)
    window = jnp.concatenate([conv_buf, xbc[:, None]], axis=1)  # (B,W,cc)
    w = p["conv_w"].value.astype(xbc.dtype)                  # (W,cc)
    conv = jnp.einsum("bwc,wc->bc", window, w)
    conv = jax.nn.silu(conv)
    new_buf = window[:, 1:]

    xin = conv[:, :d_inner]
    Bmat = conv[:, d_inner:d_inner + s.n_groups * s.d_state].reshape(
        b, s.n_groups, s.d_state)
    Cmat = conv[:, d_inner + s.n_groups * s.d_state:].reshape(
        b, s.n_groups, s.d_state)

    dt = jax.nn.softplus(
        cm.apply_dense(p["dt_proj"], x)[:, 0].astype(jnp.float32)
        + p["dt_bias"].value)                                # (B,H)
    A = -jnp.exp(p["A_log"].value)
    da = jnp.exp(dt * A)                                     # (B,H)
    xh = xin.reshape(b, nh, s.head_dim).astype(jnp.float32)
    B_h = _group_to_heads(Bmat[:, None], nh)[:, 0]           # (B,H,N)
    C_h = _group_to_heads(Cmat[:, None], nh)[:, 0]
    # state <- decay * state + dt * B ⊗ x
    upd = jnp.einsum("bhn,bhp->bhnp", B_h, xh * dt[..., None])
    state = state * da[:, :, None, None] + upd
    y = jnp.einsum("bhn,bhnp->bhp", C_h, state)              # (B,H,P)
    y = y + xh * p["D"].value[None, :, None]
    y = y.reshape(b, d_inner).astype(x.dtype)
    y = cm.rmsnorm(p["norm"], y * jax.nn.silu(z), cfg.rms_eps)
    out = cm.apply_dense(p["out_proj"], y)[:, None]          # (B,1,d_model)
    return out, state, new_buf
