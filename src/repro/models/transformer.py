"""Decoder-only transformer LM covering the dense / moe / mla / hybrid / ssm /
vlm families via config switches. Layers are stacked and scanned
(jax.lax.scan) so compile time is independent of depth.

Public surface (used by registry / launch / engine):
  init(key, cfg)                          -> Param tree
  forward(params, cfg, tokens, ...)       -> logits (train/prefill path)
  loss_fn(params, cfg, batch, ...)        -> scalar loss
  init_cache(cfg, batch, max_len, dtype)  -> decode cache pytree (Param tree)
  prefill(params, cfg, tokens, cache)     -> (logits_last, cache)
  decode_step(params, cfg, cache, token)  -> (logits, cache)
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs import (ATTN_GQA, ATTN_MLA, ATTN_NONE, FAMILY_HYBRID,
                           FAMILY_SSM, ModelConfig)
from repro.models import attention as attn
from repro.models import common as cm
from repro.models import ffn as ffn_mod
from repro.models import ssm as ssm_mod


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def block_init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 6)
    p = {}
    if cfg.attn_type == ATTN_GQA:
        p["attn_norm"] = cm.rmsnorm_init(cfg.d_model)
        p["attn"] = attn.gqa_init(ks[0], cfg)
    elif cfg.attn_type == ATTN_MLA:
        p["attn_norm"] = cm.rmsnorm_init(cfg.d_model)
        p["attn"] = attn.mla_init(ks[0], cfg)
    if cfg.ssm is not None:
        if cfg.family == FAMILY_HYBRID:
            p["ssm"] = ssm_mod.mamba2_init(ks[1], cfg)
            p["attn_out_norm"] = cm.rmsnorm_init(cfg.d_model)
            p["ssm_out_norm"] = cm.rmsnorm_init(cfg.d_model)
        else:
            p["ssm_norm"] = cm.rmsnorm_init(cfg.d_model)
            p["ssm"] = ssm_mod.mamba2_init(ks[1], cfg)
    if cfg.d_ff > 0:
        p["ffn_norm"] = cm.rmsnorm_init(cfg.d_model)
        if cfg.moe is not None:
            p["ffn"] = ffn_mod.moe_init(ks[2], cfg)
        else:
            p["ffn"] = ffn_mod.swiglu_init(ks[2], cfg.d_model, cfg.d_ff)
    return p


def init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 4)
    p = {
        "embed": cm.embedding(ks[0], cfg.vocab_size, cfg.d_model),
        "layers": cm.stack_layers(lambda k: block_init(k, cfg), ks[1],
                                  cfg.n_layers),
        "final_norm": cm.rmsnorm_init(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        p["unembed"] = cm.dense(ks[2], cfg.d_model, cfg.vocab_size,
                                ("embed", "vocab"))
    if cfg.n_prefix_embeds:
        # projection for precomputed modality embeddings (frontend stub)
        p["prefix_proj"] = cm.dense(ks[3], cfg.d_model, cfg.d_model,
                                    ("embed", "embed2"))
    return p


def layer_windows(cfg: ModelConfig):
    """Per-layer sliding window (0 = full attention)."""
    if cfg.sliding_window <= 0:
        return None
    w = jnp.full((cfg.n_layers,), cfg.sliding_window, jnp.int32)
    if cfg.full_attn_layers:
        idx = jnp.array(cfg.full_attn_layers)
        w = w.at[idx].set(0)
    return w


# ---------------------------------------------------------------------------
# Forward (train / prefill path)
# ---------------------------------------------------------------------------

def _block_forward(lp, x, cfg, window, positions, moe_ctx):
    """One layer. x: (B,S,d). window: python int 0 or traced int32 scalar.

    The constrain() on each mixer output pins the tensor-parallel
    all-reduce to the NARROW dtype: without it XLA fuses the bf16
    round-trip into downstream f32 consumers (residual + rmsnorm) and
    all-reduces the f32 carrier — 2x the ICI bytes (§Perf iteration 3)."""
    from repro.distributed import sharding as shd
    x = shd.constrain(x, ("batch", "seq", "embed_act"))
    if "attn" in lp:
        h = cm.rmsnorm(lp["attn_norm"], x, cfg.rms_eps)
        if cfg.attn_type == ATTN_MLA:
            a = attn.mla_forward(lp["attn"], h, cfg, positions=positions)
        else:
            a = attn.gqa_forward(lp["attn"], h, cfg, positions=positions,
                                 window=window)
        if cfg.family == FAMILY_HYBRID:
            s = ssm_mod.mamba2_forward(lp["ssm"], h, cfg)
            mix = 0.5 * (cm.rmsnorm(lp["attn_out_norm"], a, cfg.rms_eps)
                         + cm.rmsnorm(lp["ssm_out_norm"], s, cfg.rms_eps))
            x = x + mix
        else:
            x = x + a
    elif "ssm" in lp:
        h = cm.rmsnorm(lp["ssm_norm"], x, cfg.rms_eps)
        x = x + ssm_mod.mamba2_forward(lp["ssm"], h, cfg)
    if "ffn" in lp:
        h = cm.rmsnorm(lp["ffn_norm"], x, cfg.rms_eps)
        if cfg.moe is not None:
            if moe_ctx and moe_ctx.get("impl") == "shardmap":
                f = ffn_mod.moe_forward_shardmap(
                    lp["ffn"], h, cfg, moe_ctx["mesh"],
                    dp_axes=moe_ctx["dp_axes"])
            else:
                f = ffn_mod.moe_forward_gather(lp["ffn"], h, cfg)
        else:
            f = ffn_mod.swiglu(lp["ffn"], h)
        x = x + f
    return x


def embed_inputs(params, cfg, tokens, prefix_embeds=None, dtype=jnp.bfloat16):
    emb = params["embed"]["embedding"].value
    x = jnp.take(emb, tokens, axis=0).astype(dtype)
    if cfg.n_prefix_embeds and prefix_embeds is not None:
        pfx = cm.apply_dense(params["prefix_proj"],
                             prefix_embeds.astype(dtype))
        x = jnp.concatenate([pfx, x], axis=1)
    return x


def forward(params, cfg: ModelConfig, tokens, *, prefix_embeds=None,
            dtype=jnp.bfloat16, remat=False, moe_ctx=None,
            inputs_embeds=None):
    """tokens: (B, S_text) int32. Returns logits (B, S_total, vocab) f32."""
    if inputs_embeds is not None:
        x = inputs_embeds.astype(dtype)
    else:
        x = embed_inputs(params, cfg, tokens, prefix_embeds, dtype)
    seq = x.shape[1]
    positions = jnp.arange(seq)[None, :]
    windows = layer_windows(cfg)

    def body(x, layer_in):
        lp, win = layer_in
        y = _block_forward(lp, x, cfg, win if win is not None else 0,
                           positions, moe_ctx)
        return y, None

    if remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)

    win_xs = windows if windows is not None else None
    x, _ = jax.lax.scan(body, x, (params["layers"], win_xs))
    x = cm.rmsnorm(params["final_norm"], x, cfg.rms_eps)
    return unembed(params, cfg, x)


def unembed(params, cfg, x):
    from repro.distributed import sharding as shd
    if cfg.tie_embeddings:
        emb = params["embed"]["embedding"].value
        logits = jnp.einsum("bsd,vd->bsv", x, emb.astype(x.dtype)).astype(
            jnp.float32)
    else:
        logits = cm.apply_dense(params["unembed"], x).astype(jnp.float32)
    return shd.constrain(logits, ("batch", "seq", "vocab"))


def loss_fn(params, cfg: ModelConfig, batch, *, dtype=jnp.bfloat16,
            remat=True, moe_ctx=None):
    """batch: {"tokens": (B,S)} (+ "prefix_embeds" | "enc_embeds")."""
    tokens = batch["tokens"]
    logits = forward(params, cfg, tokens,
                     prefix_embeds=batch.get("prefix_embeds"),
                     dtype=dtype, remat=remat, moe_ctx=moe_ctx)
    npfx = logits.shape[1] - tokens.shape[1]
    if npfx:
        logits = logits[:, npfx:]
    labels = jnp.concatenate(
        [tokens[:, 1:], jnp.zeros_like(tokens[:, :1])], axis=1)
    mask = jnp.concatenate(
        [jnp.ones_like(tokens[:, 1:], jnp.float32),
         jnp.zeros_like(tokens[:, :1], jnp.float32)], axis=1)
    return cm.softmax_cross_entropy(logits, labels, mask)


# ---------------------------------------------------------------------------
# Decode cache
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16, per_slot_pos: bool = False,
               kv_dtype=None):
    """Returns a Param tree so the sharding rules apply to cache leaves too.

    per_slot_pos=True allocates a (batch,) position vector — each slot
    decodes at its own depth (continuous batching, repro.engine).
    kv_dtype=jnp.int8 stores a quantized GQA cache + per-(pos, head)
    scales (§Perf pair C: decode streams half the bytes)."""
    L = cfg.n_layers
    c = {}
    if cfg.attn_type == ATTN_GQA:
        kv = (L, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
        axes = ("layer", "batch", "kv_seq", "kv_heads", "head_dim")
        if kv_dtype == jnp.int8:
            c["k"] = cm.Param(jnp.zeros(kv, jnp.int8), axes)
            c["v"] = cm.Param(jnp.zeros(kv, jnp.int8), axes)
            sc = (L, batch, max_len, cfg.n_kv_heads)
            sc_axes = ("layer", "batch", "kv_seq", "kv_heads")
            c["k_scale"] = cm.Param(jnp.zeros(sc, jnp.bfloat16), sc_axes)
            c["v_scale"] = cm.Param(jnp.zeros(sc, jnp.bfloat16), sc_axes)
        else:
            c["k"] = cm.Param(jnp.zeros(kv, dtype), axes)
            c["v"] = cm.Param(jnp.zeros(kv, dtype), axes)
    elif cfg.attn_type == ATTN_MLA:
        m = cfg.mla
        c["ckv"] = cm.Param(
            jnp.zeros((L, batch, max_len, m.kv_lora_rank), dtype),
            ("layer", "batch", "kv_seq", "kv_lora"))
        c["krope"] = cm.Param(
            jnp.zeros((L, batch, max_len, m.qk_rope_head_dim), dtype),
            ("layer", "batch", "kv_seq", "head_dim"))
    if cfg.ssm is not None:
        d_inner, nh, conv_ch = ssm_mod.dims(cfg)
        c["ssm_state"] = cm.Param(
            jnp.zeros((L, batch, nh, cfg.ssm.d_state, cfg.ssm.head_dim),
                      jnp.float32),
            ("layer", "batch", "ssm_heads", "ssm_state", "head_dim"))
        c["conv_buf"] = cm.Param(
            jnp.zeros((L, batch, cfg.ssm.conv_width - 1, conv_ch), dtype),
            ("layer", "batch", "conv", "ssm_conv_ch"))
    if per_slot_pos:
        c["pos"] = cm.Param(jnp.zeros((batch,), jnp.int32), ("batch",))
    else:
        c["pos"] = cm.Param(jnp.zeros((), jnp.int32), ())
    return c


def _block_decode(lp, cache_l, x, pos, cfg, window):
    upd = {}
    if "attn" in lp:
        h = cm.rmsnorm(lp["attn_norm"], x, cfg.rms_eps)
        if cfg.attn_type == ATTN_MLA:
            a, ckv, krope = attn.mla_decode(
                lp["attn"], h, cache_l["ckv"], cache_l["krope"], pos, cfg)
            upd["ckv"], upd["krope"] = ckv, krope
        elif "k_scale" in cache_l:        # int8-quantized cache
            a, ck, cv, ks, vs = attn.gqa_decode_q8(
                lp["attn"], h, cache_l["k"], cache_l["v"],
                cache_l["k_scale"], cache_l["v_scale"], pos, cfg,
                window=window)
            upd["k"], upd["v"] = ck, cv
            upd["k_scale"], upd["v_scale"] = ks, vs
        else:
            a, ck, cv = attn.gqa_decode(
                lp["attn"], h, cache_l["k"], cache_l["v"], pos, cfg,
                window=window)
            upd["k"], upd["v"] = ck, cv
        if cfg.family == FAMILY_HYBRID:
            s, st, buf = ssm_mod.mamba2_decode(
                lp["ssm"], h, cache_l["ssm_state"], cache_l["conv_buf"], cfg)
            upd["ssm_state"], upd["conv_buf"] = st, buf
            mix = 0.5 * (cm.rmsnorm(lp["attn_out_norm"], a, cfg.rms_eps)
                         + cm.rmsnorm(lp["ssm_out_norm"], s, cfg.rms_eps))
            x = x + mix
        else:
            x = x + a
    elif "ssm" in lp:
        h = cm.rmsnorm(lp["ssm_norm"], x, cfg.rms_eps)
        s, st, buf = ssm_mod.mamba2_decode(
            lp["ssm"], h, cache_l["ssm_state"], cache_l["conv_buf"], cfg)
        upd["ssm_state"], upd["conv_buf"] = st, buf
        x = x + s
    if "ffn" in lp:
        h = cm.rmsnorm(lp["ffn_norm"], x, cfg.rms_eps)
        if cfg.moe is not None:
            x = x + ffn_mod.moe_forward_gather(lp["ffn"], h, cfg)
        else:
            x = x + ffn_mod.swiglu(lp["ffn"], h)
    return x, upd


def decode_step(params, cfg: ModelConfig, cache, token, *,
                dtype=jnp.bfloat16):
    """token: (B, 1) int32. Returns (logits (B,1,V) f32, new cache)."""
    pos = cache["pos"].value
    emb = params["embed"]["embedding"].value
    x = jnp.take(emb, token, axis=0).astype(dtype)
    windows = layer_windows(cfg)

    cache_vals = {k: v.value for k, v in cache.items() if k != "pos"}

    def body(x, layer_in):
        lp, cl, win = layer_in
        y, upd = _block_decode(lp, cl, x, pos, cfg,
                               win if win is not None else 0)
        # keep unmodified cache entries as-is so the scan carry matches
        out = {k: upd.get(k, cl[k]) for k in cl}
        return y, out

    x, new_cache_vals = jax.lax.scan(
        body, x, (params["layers"], cache_vals, windows))
    x = cm.rmsnorm(params["final_norm"], x, cfg.rms_eps)
    logits = unembed(params, cfg, x)
    new_cache = {k: cm.Param(v, cache[k].axes)
                 for k, v in new_cache_vals.items()}
    new_cache["pos"] = cm.Param(pos + 1, cache["pos"].axes)
    return logits, new_cache


def prefill(params, cfg: ModelConfig, tokens, *, prefix_embeds=None,
            max_len: Optional[int] = None, dtype=jnp.bfloat16):
    """Run the full-sequence forward while building the decode cache.

    Returns (last-position logits, cache). Implemented as a scan over layers
    mirroring `forward` but capturing K/V (or SSM state) per layer.
    """
    x = embed_inputs(params, cfg, tokens, prefix_embeds, dtype)
    b, seq = x.shape[0], x.shape[1]
    max_len = max_len or seq
    positions = jnp.arange(seq)[None, :]
    windows = layer_windows(cfg)

    def body(x, layer_in):
        lp, win = layer_in
        win = win if win is not None else 0
        caches = {}
        if "attn" in lp:
            h = cm.rmsnorm(lp["attn_norm"], x, cfg.rms_eps)
            if cfg.attn_type == ATTN_MLA:
                m = cfg.mla
                c_kv = cm.apply_dense(lp["attn"]["kv_down"], h)
                k_rope = cm.apply_dense(lp["attn"]["k_rope"], h)[:, :, 0]
                k_rope = cm.apply_rope(k_rope[:, :, None], positions,
                                       cfg.rope_theta)[:, :, 0]
                caches["ckv"] = _pad_to(c_kv, max_len, 1).astype(dtype)
                caches["krope"] = _pad_to(k_rope, max_len, 1).astype(dtype)
                a = attn.mla_forward(lp["attn"], h, cfg, positions=positions)
            else:
                q, k, v = attn.gqa_project_qkv(lp["attn"], h, positions,
                                               cfg.rope_theta)
                caches["k"] = _pad_to(k, max_len, 1).astype(dtype)
                caches["v"] = _pad_to(v, max_len, 1).astype(dtype)
                o = attn.chunked_attention(q, k, v, causal=True, window=win)
                a = cm.apply_dense(lp["attn"]["o"], o, in_dims=2)
            if cfg.family == FAMILY_HYBRID:
                s, (st, buf) = ssm_mod.mamba2_forward(lp["ssm"], h, cfg,
                                                      return_state=True)
                caches["ssm_state"], caches["conv_buf"] = st, buf.astype(dtype)
                mix = 0.5 * (cm.rmsnorm(lp["attn_out_norm"], a, cfg.rms_eps)
                             + cm.rmsnorm(lp["ssm_out_norm"], s, cfg.rms_eps))
                x = x + mix
            else:
                x = x + a
        elif "ssm" in lp:
            h = cm.rmsnorm(lp["ssm_norm"], x, cfg.rms_eps)
            s, (st, buf) = ssm_mod.mamba2_forward(lp["ssm"], h, cfg,
                                                  return_state=True)
            caches["ssm_state"], caches["conv_buf"] = st, buf.astype(dtype)
            x = x + s
        if "ffn" in lp:
            h = cm.rmsnorm(lp["ffn_norm"], x, cfg.rms_eps)
            if cfg.moe is not None:
                x = x + ffn_mod.moe_forward_gather(lp["ffn"], h, cfg)
            else:
                x = x + ffn_mod.swiglu(lp["ffn"], h)
        return x, caches

    x, cache_stk = jax.lax.scan(body, x, (params["layers"], windows))
    x = cm.rmsnorm(params["final_norm"], x, cfg.rms_eps)
    logits_last = unembed(params, cfg, x[:, -1:])

    axes_map = {
        "k": ("layer", "batch", "kv_seq", "kv_heads", "head_dim"),
        "v": ("layer", "batch", "kv_seq", "kv_heads", "head_dim"),
        "ckv": ("layer", "batch", "kv_seq", "kv_lora"),
        "krope": ("layer", "batch", "kv_seq", "head_dim"),
        "ssm_state": ("layer", "batch", "ssm_heads", "ssm_state", "head_dim"),
        "conv_buf": ("layer", "batch", "conv", "ssm_conv_ch"),
    }
    cache = {k: cm.Param(v, axes_map[k]) for k, v in cache_stk.items()}
    total = seq + (cfg.n_prefix_embeds if prefix_embeds is not None else 0)
    cache["pos"] = cm.Param(jnp.asarray(min(total, max_len), jnp.int32), ())
    return logits_last, cache


def _pad_to(x, n, axis):
    if x.shape[axis] == n:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, n - x.shape[axis])
    return jnp.pad(x, pad)
