"""Uniform model interface over the zoo.

``build(cfg)`` returns a :class:`ModelBundle` exposing init / loss_fn /
prefill / decode_step / init_cache / batch_specs regardless of family.

Shape conventions for the assigned input-shape grid:
  train_4k      tokens (B, S). VLM: S_text = S - n_prefix (patch embeds fill
                the rest). Enc-dec: S_enc = S_dec = S // 2.
  prefill_32k   decoder prefill of length S (enc-dec: encoder ctx = 4096).
  decode_*      one token against a KV cache (or SSM state) of length S.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs import (FAMILY_AUDIO, FAMILY_SSM, FAMILY_VLM, InputShape,
                           ModelConfig)
from repro.models import encdec, transformer

ENC_CTX_SERVE = 4096  # encoder context frames for enc-dec serve shapes


@dataclasses.dataclass
class ModelBundle:
    cfg: ModelConfig
    init: Callable
    loss_fn: Callable          # (params, batch, **kw) -> scalar
    prefill: Callable          # (params, batch, max_len, **kw) -> (logits, cache)
    decode_step: Callable      # (params, cache, token, **kw) -> (logits, cache)
    init_cache: Callable       # (batch, max_len, dtype) -> cache tree
    batch_specs: Callable      # (InputShape) -> dict of ShapeDtypeStruct


def build(cfg: ModelConfig) -> ModelBundle:
    if cfg.is_encoder_decoder:
        return _build_encdec(cfg)
    return _build_decoder(cfg)


def _build_decoder(cfg: ModelConfig) -> ModelBundle:
    def loss_fn(params, batch, *, dtype=jnp.bfloat16, remat=True,
                moe_ctx=None):
        return transformer.loss_fn(params, cfg, batch, dtype=dtype,
                                   remat=remat, moe_ctx=moe_ctx)

    def prefill_fn(params, batch, max_len=None, *, dtype=jnp.bfloat16):
        return transformer.prefill(params, cfg, batch["tokens"],
                                   prefix_embeds=batch.get("prefix_embeds"),
                                   max_len=max_len, dtype=dtype)

    def decode_fn(params, cache, token, *, dtype=jnp.bfloat16):
        return transformer.decode_step(params, cfg, cache, token,
                                       dtype=dtype)

    def init_cache(batch, max_len, dtype=jnp.bfloat16,
                   per_slot_pos=False, kv_dtype=None):
        return transformer.init_cache(cfg, batch, max_len, dtype,
                                      per_slot_pos=per_slot_pos,
                                      kv_dtype=kv_dtype)

    def batch_specs(shape: InputShape):
        b, s = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        if shape.kind == "decode":
            return {"token": jax.ShapeDtypeStruct((b, 1), i32)}
        specs = {}
        s_text = s
        if cfg.family == FAMILY_VLM:
            s_text = s - cfg.n_prefix_embeds
            specs["prefix_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.n_prefix_embeds, cfg.d_model), jnp.bfloat16)
        specs["tokens"] = jax.ShapeDtypeStruct((b, s_text), i32)
        return specs

    return ModelBundle(cfg, lambda key: transformer.init(key, cfg), loss_fn,
                       prefill_fn, decode_fn, init_cache, batch_specs)


def _build_encdec(cfg: ModelConfig) -> ModelBundle:
    def loss_fn(params, batch, *, dtype=jnp.bfloat16, remat=True,
                moe_ctx=None):
        return encdec.loss_fn(params, cfg, batch, dtype=dtype, remat=remat)

    def prefill_fn(params, batch, max_len=None, *, dtype=jnp.bfloat16):
        return encdec.prefill(params, cfg, batch["tokens"],
                              batch["enc_embeds"], max_len=max_len,
                              dtype=dtype)

    def decode_fn(params, cache, token, *, dtype=jnp.bfloat16):
        return encdec.decode_step(params, cfg, cache, token, dtype=dtype)

    def init_cache(batch, max_len, dtype=jnp.bfloat16,
                   enc_len=ENC_CTX_SERVE):
        return encdec.init_cache(cfg, batch, max_len, enc_len, dtype)

    def batch_specs(shape: InputShape):
        b, s = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        if shape.kind == "decode":
            return {"token": jax.ShapeDtypeStruct((b, 1), i32)}
        if shape.kind == "train":
            s_enc = s_dec = s // 2
        else:  # prefill
            s_enc, s_dec = ENC_CTX_SERVE, s
        return {
            "tokens": jax.ShapeDtypeStruct((b, s_dec), i32),
            "enc_embeds": jax.ShapeDtypeStruct((b, s_enc, cfg.d_model),
                                               jnp.bfloat16),
        }

    return ModelBundle(cfg, lambda key: encdec.init(key, cfg), loss_fn,
                       prefill_fn, decode_fn, init_cache, batch_specs)
