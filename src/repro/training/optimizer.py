"""AdamW with global-norm clipping and cosine schedule — pure pytree ops.

Optimizer state mirrors the param tree (Param-wrapped, same logical axes) so
the FSDP sharding rules apply to the m/v moments unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.models import common as cm


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def init_state(params):
    zeros = lambda p: cm.Param(jnp.zeros_like(p.value), p.axes)
    return {
        "m": jax.tree.map(zeros, params, is_leaf=cm.is_param),
        "v": jax.tree.map(zeros, params, is_leaf=cm.is_param),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def apply_updates(cfg: AdamWConfig, params, grads, opt_state):
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    lr = schedule(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))

    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m2 = cfg.b1 * m + (1 - cfg.b1) * g
        v2 = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m2 / b1c
        vhat = v2 / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        newp = p - lr * (delta + cfg.weight_decay * p)
        return newp, m2, v2

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m,
                                                 flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    new_state = {"m": new_m, "v": new_v, "step": step}
    return new_p, new_state, {"grad_norm": gnorm, "lr": lr}
