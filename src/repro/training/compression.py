"""Gradient compression: blockwise int8 quantization (simulated transport).

At 1000+-node scale the cross-pod gradient all-reduce is the slowest
collective (it crosses the inter-pod links). Blockwise int8 with a per-block
fp32 scale cuts those bytes 4x vs fp32 (2x vs bf16). Under GSPMD we cannot
intercept the all-reduce itself from jit-level code, so this module
quantizes/dequantizes the gradient tree around the reduction point: the
numerics (and the compression error) are exactly those of an int8-compressed
all-reduce; the byte saving is realized when the same transform runs inside a
shard_map collective (see ``compressed_psum``)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

BLOCK = 256


def _quant(g):
    flat = g.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    q = jnp.clip(jnp.round(blocks / jnp.maximum(scale, 1e-12)), -127, 127)
    return q.astype(jnp.int8), scale


def _dequant(q, scale, shape):
    deq = (q.astype(jnp.float32) * scale).reshape(-1)
    return deq[:_size(shape)].reshape(shape)


def _size(shape):
    n = 1
    for s in shape:
        n *= int(s)
    return n


def compress_decompress(grads):
    """Quantize->dequantize each gradient leaf (error model of int8 AR)."""
    def leaf(g):
        q, scale = _quant(g)
        return _dequant(q, scale, g.shape).astype(g.dtype)
    return jax.tree.map(leaf, grads)


def compressed_psum(x, axis_name):
    """int8-compressed psum for use inside shard_map: quantize locally,
    all-reduce the int32-accumulated quantized values, dequantize."""
    q, scale = _quant(x)
    qsum = jax.lax.psum(q.astype(jnp.int32), axis_name)
    ssum = jax.lax.psum(scale, axis_name)
    n = jax.lax.psum(jnp.ones(()), axis_name)
    deq = (qsum.astype(jnp.float32) * (ssum / n)).reshape(-1)
    return deq[:_size(x.shape)].reshape(x.shape).astype(x.dtype)
