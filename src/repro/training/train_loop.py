"""Train-step construction: value_and_grad + AdamW, with optional microbatch
gradient accumulation (lax.scan) and int8 error-feedback gradient compression
applied before the cross-pod all-reduce (see training/compression.py)."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.training import optimizer as opt_mod
from repro.training import compression as comp_mod


def init_train_state(bundle, key):
    params = bundle.init(key)
    return {"params": params, "opt": opt_mod.init_state(params)}


def make_train_step(bundle, opt_cfg: opt_mod.AdamWConfig, *,
                    dtype=jnp.bfloat16, remat=True, moe_ctx=None,
                    microbatches: int = 1, compress_grads: bool = False):
    """Returns train_step(state, batch) -> (state, metrics)."""

    def loss_of(params, batch):
        return bundle.loss_fn(params, batch, dtype=dtype, remat=remat,
                              moe_ctx=moe_ctx)

    def grads_of(params, batch):
        if microbatches <= 1:
            return jax.value_and_grad(loss_of)(params, batch)

        def micro(carry, mb):
            loss, acc = jax.value_and_grad(loss_of)(params, mb)
            return (carry[0] + loss,
                    jax.tree.map(jnp.add, carry[1], acc)), None

        mbs = jax.tree.map(
            lambda x: x.reshape((microbatches, x.shape[0] // microbatches)
                                + x.shape[1:]), batch)
        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                            params)
        (loss_sum, gsum), _ = jax.lax.scan(micro, (jnp.zeros(()), zero), mbs)
        inv = 1.0 / microbatches
        return loss_sum * inv, jax.tree.map(lambda g: g * inv, gsum)

    def train_step(state, batch):
        loss, grads = grads_of(state["params"], batch)
        if compress_grads:
            grads = comp_mod.compress_decompress(grads)
        params, opt_state, metrics = opt_mod.apply_updates(
            opt_cfg, state["params"], grads, state["opt"])
        metrics["loss"] = loss
        return {"params": params, "opt": opt_state}, metrics

    return train_step
