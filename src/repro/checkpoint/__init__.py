"""Atomic sharded checkpointing (sync + async) with mesh-agnostic restore."""
from repro.checkpoint.checkpoint import (save, restore, latest_step,  # noqa: F401
                                         committed_steps,
                                         AsyncCheckpointer)
