"""Sharded, atomic, mesh-agnostic checkpointing.

Layout (one directory per step):

    ckpt_dir/
      step_000120.tmp-<nonce>/   # written first
        manifest.json            # leaf paths, shapes, dtypes, logical axes
        <leaf>.npy               # one file per leaf (per host-shard at scale)
      step_000120/               # atomic rename == commit marker

Properties that matter at 1000+ nodes:
  * atomicity: a crash mid-write leaves only a .tmp dir, never a
    half-readable step; ``latest_step`` skips uncommitted dirs
  * mesh-agnostic restore: leaves are stored as *logical* arrays + axis
    names; ``restore`` re-materializes them under any mesh whose sharding
    rules divide the dims (elastic re-meshing = save on 512 chips, restore
    on 256)
  * async save: serialization runs on a writer thread; training only blocks
    if it laps an in-flight save (double-buffering semantics)
  * keep-last-k garbage collection
  * integrity: per-leaf SHA-256 in the manifest, verified on restore
"""
from __future__ import annotations

import hashlib
import json
import os
import queue
import shutil
import threading
import uuid
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

from repro.models import common as cm


def _flatten(tree) -> Dict[str, cm.Param]:
    out = {}

    def rec(node, path):
        if cm.is_param(node):
            out["/".join(path)] = node
            return
        if isinstance(node, dict):
            for k in sorted(node):
                rec(node[k], path + [str(k)])
            return
        out["/".join(path)] = cm.Param(node, None)  # bare leaf

    rec(tree, [])
    return out


def _unflatten(flat: Dict[str, Any]):
    root: Dict[str, Any] = {}
    for path, val in flat.items():
        parts = path.split("/")
        d = root
        for p in parts[:-1]:
            d = d.setdefault(p, {})
        d[parts[-1]] = val
    return root


def _leaf_file(name: str) -> str:
    return name.replace("/", "__") + ".npy"


def save(ckpt_dir: str, step: int, state, *, keep_last: int = 3,
         extra_meta: Optional[dict] = None) -> str:
    """Synchronous atomic save. Returns the committed directory."""
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = _flatten(state)
    tmp = os.path.join(ckpt_dir, f"step_{step:08d}.tmp-{uuid.uuid4().hex[:8]}")
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    os.makedirs(tmp)
    manifest = {"step": step, "leaves": {}, "meta": extra_meta or {}}
    for name, p in flat.items():
        arr = np.asarray(jax.device_get(p.value))
        fn = _leaf_file(name)
        with open(os.path.join(tmp, fn), "wb") as f:
            np.save(f, arr)
        with open(os.path.join(tmp, fn), "rb") as f:
            digest = hashlib.sha256(f.read()).hexdigest()
        manifest["leaves"][name] = {
            "file": fn, "shape": list(arr.shape), "dtype": str(arr.dtype),
            "axes": list(p.axes) if p.axes is not None else None,
            "sha256": digest,
        }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                      # commit
    _gc(ckpt_dir, keep_last)
    return final


def _gc(ckpt_dir: str, keep_last: int) -> None:
    steps = committed_steps(ckpt_dir)
    for s in steps[:-keep_last] if keep_last > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)
    # drop orphaned tmp dirs (crashed writers)
    for d in os.listdir(ckpt_dir):
        if ".tmp-" in d:
            shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def committed_steps(ckpt_dir: str):
    out = []
    if not os.path.isdir(ckpt_dir):
        return out
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and ".tmp" not in d and os.path.exists(
                os.path.join(ckpt_dir, d, "manifest.json")):
            out.append(int(d.split("_")[1]))
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = committed_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, step: Optional[int] = None, *,
            mesh=None, rules: Optional[dict] = None,
            verify: bool = True) -> Tuple[int, Any]:
    """Load a checkpoint; with (mesh, rules) the leaves are placed with the
    target NamedShardings (elastic re-meshing path)."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no committed checkpoint in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    flat = {}
    for name, info in manifest["leaves"].items():
        path = os.path.join(d, info["file"])
        with open(path, "rb") as f:
            raw = f.read()
        if verify:
            digest = hashlib.sha256(raw).hexdigest()
            if digest != info["sha256"]:
                raise IOError(f"checksum mismatch for {name} in {d}")
        arr = np.load(path)
        axes = tuple(info["axes"]) if info["axes"] is not None else None
        if mesh is not None and rules is not None and axes is not None:
            from repro.distributed import sharding as shd
            sharding = shd.NamedSharding(
                mesh, shd.spec_for(arr.shape, axes, rules, mesh))
            val = jax.device_put(arr, sharding)
        else:
            val = jax.numpy.asarray(arr)
        flat[name] = cm.Param(val, axes) if axes is not None else val
    return step, _unflatten(flat)


class AsyncCheckpointer:
    """Writer-thread checkpointer: ``save`` enqueues a host copy of the
    state and returns; at most one save is in flight (a second enqueue
    blocks until the writer drains — double buffering)."""

    def __init__(self, ckpt_dir: str, keep_last: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep_last = keep_last
        self._q: "queue.Queue" = queue.Queue(maxsize=1)
        self._err: Optional[BaseException] = None
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            step, state = item
            try:
                save(self.ckpt_dir, step, state, keep_last=self.keep_last)
            except BaseException as e:   # surfaced on next call / close
                self._err = e
            finally:
                self._q.task_done()

    def save(self, step: int, state) -> None:
        if self._err:
            raise self._err
        host_state = jax.tree.map(
            lambda p: cm.Param(np.asarray(jax.device_get(p.value)), p.axes),
            state, is_leaf=cm.is_param)
        self._q.put((step, host_state))   # blocks iff a save is in flight

    def wait(self) -> None:
        self._q.join()
        if self._err:
            raise self._err

    def close(self) -> None:
        self.wait()
        self._q.put(None)
        self._thread.join(timeout=10)
