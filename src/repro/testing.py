"""Shared fake backends for the test suite and benchmarks.

One metering implementation (instead of per-file copies drifting apart):
the driver-equivalence and coalescing suites assert exact call counts,
batch groupings, and per-call latencies against these fakes, and
``benchmarks/bench_coalesce.py`` uses the same class so its measured
walls are comparable with the tests' acceptance bounds.
"""
from __future__ import annotations

import threading
import time
from typing import Optional, Sequence

from repro.core import backends as bk
from repro.core import plan as plan_ir
from repro.core.cost import TierSpec


class EchoOracle:
    """Deterministic value-derived answers — lets tests assert outputs."""

    def answer(self, op, value):
        return f"A:{value}"

    def answer_reduce(self, op, values):
        return len(list(values))


class ConstOracle:
    """Always-true filter oracle (every row survives)."""

    def answer(self, op, value):
        return True

    def answer_reduce(self, op, values):
        return len(list(values))


class KindOracle:
    """Kind-aware deterministic oracle for multi-operator pipelines:
    filters pass every row, maps echo the value, reduces count — so
    filter -> map -> reduce chains produce assertable outputs."""

    def answer(self, op, value):
        return True if op.kind == plan_ir.FILTER else f"A:{value}"

    def answer_reduce(self, op, values):
        return len(list(values))


def tagged_table(tag: str, n: int = 32):
    """A one-column table whose values are tagged (``tag-i``) — paired
    with :func:`tagged_plan` so distinct tags never share cache keys."""
    from repro.core.table import Table
    return Table({"v": [f"{tag}-{i}" for i in range(n)]}, name=tag)


def tagged_plan(tag: str, reduce_tail: bool = False) -> plan_ir.LogicalPlan:
    """filter -> map (-> reduce) over :func:`tagged_table`, with the tag
    baked into every instruction: queries built from different tags
    never overlap on ``OutputCache`` keys, so their billing is
    independent of co-tenants on a shared server — the property the
    serve suite's solo-identity assertions and ``bench_serve`` rely on."""
    ops = [
        plan_ir.Operator(plan_ir.FILTER, f"keep-{tag}", "v"),
        plan_ir.Operator(plan_ir.MAP, f"annotate-{tag}", "v", "a"),
    ]
    if reduce_tail:
        ops.append(plan_ir.Operator(plan_ir.REDUCE, f"count-{tag}", "v"))
    return plan_ir.LogicalPlan(tuple(ops))


def result_fingerprint(res):
    """Canonical byte-comparable key for an ExecutionResult of a
    :func:`tagged_plan` run (reduce scalar, or rowids + mapped column)."""
    from repro.core import executor as ex
    if res.is_reduce:
        return ("reduce", res.scalar)
    return ("table", tuple(res.table.columns[ex.ROWID]),
            tuple(map(str, res.table.columns["a"])))


class SleepBackend:
    """Always-correct fake backend whose calls *really* sleep.

    Each (batched) call bills ``delay_s`` metered latency — exactly like
    SimulatedBackend bills its modeled latency — and sleeps ``sleep_s``
    real seconds (defaults to ``delay_s``; pass ``sleep_s=0.0`` for
    event-time-only tests that want 1s modeled calls without 1s waits).
    Counts calls and records each call's value group under a lock, so
    tests can assert the exact batch grouping the runtime formed."""

    def __init__(self, oracle, delay_s: float = 0.05, name: str = "m*",
                 capability: float = 1.01,
                 sleep_s: Optional[float] = None):
        self.tier = TierSpec(name, capability, 0.0, 0.0, delay_s, 0.0)
        self.oracle = oracle
        self.delay_s = delay_s
        self.sleep_s = delay_s if sleep_s is None else sleep_s
        self.calls_made = 0
        self.groups = []
        self._lock = threading.Lock()

    def run_values(self, op, values: Sequence, meter=None,
                   batch_size: int = 1):
        values = list(values)
        if op.kind == plan_ir.REDUCE:
            n_calls = 1
            outs = [self.oracle.answer_reduce(op, values)]
        else:
            n_calls = max(1, -(-len(values) // batch_size))
            outs = [self.oracle.answer(op, v) for v in values]
        with self._lock:
            self.calls_made += n_calls
            self.groups.append(tuple(map(str, values)))
        if self.sleep_s:
            time.sleep(self.sleep_s * n_calls)
        if meter is not None:
            meter.record(self.tier.name,
                         bk.Usage(calls=n_calls, tok_in=8.0 * len(values),
                                  tok_out=4.0 * n_calls, usd=0.0,
                                  latency_s=self.delay_s * n_calls),
                         per_call_latency_s=[self.delay_s] * n_calls)
        return outs
