"""Shared fake backends for the test suite and benchmarks.

One metering implementation (instead of per-file copies drifting apart):
the driver-equivalence and coalescing suites assert exact call counts,
batch groupings, and per-call latencies against these fakes, and
``benchmarks/bench_coalesce.py`` uses the same class so its measured
walls are comparable with the tests' acceptance bounds.
:class:`EmbeddingOracle` plays the same role for the tier-0 cascade:
a deterministic encoder whose cosine scores track the capability
simulator's difficulty draws, shared by the cascade tests and
``benchmarks/bench_cascade.py``.
"""
from __future__ import annotations

import hashlib
import math
import threading
import time
from typing import Optional, Sequence

import numpy as np

from repro.core import backends as bk
from repro.core import plan as plan_ir
from repro.core import runtime as rt
from repro.core.cost import TierSpec


class EchoOracle:
    """Deterministic value-derived answers — lets tests assert outputs."""

    def answer(self, op, value):
        return f"A:{value}"

    def answer_reduce(self, op, values):
        return len(list(values))


class ConstOracle:
    """Always-true filter oracle (every row survives)."""

    def answer(self, op, value):
        return True

    def answer_reduce(self, op, values):
        return len(list(values))


class KindOracle:
    """Kind-aware deterministic oracle for multi-operator pipelines:
    filters pass every row, maps echo the value, reduces count — so
    filter -> map -> reduce chains produce assertable outputs."""

    def answer(self, op, value):
        return True if op.kind == plan_ir.FILTER else f"A:{value}"

    def answer_reduce(self, op, values):
        return len(list(values))


def tagged_table(tag: str, n: int = 32):
    """A one-column table whose values are tagged (``tag-i``) — paired
    with :func:`tagged_plan` so distinct tags never share cache keys."""
    from repro.core.table import Table
    return Table({"v": [f"{tag}-{i}" for i in range(n)]}, name=tag)


def tagged_plan(tag: str, reduce_tail: bool = False) -> plan_ir.LogicalPlan:
    """filter -> map (-> reduce) over :func:`tagged_table`, with the tag
    baked into every instruction: queries built from different tags
    never overlap on ``OutputCache`` keys, so their billing is
    independent of co-tenants on a shared server — the property the
    serve suite's solo-identity assertions and ``bench_serve`` rely on."""
    ops = [
        plan_ir.Operator(plan_ir.FILTER, f"keep-{tag}", "v"),
        plan_ir.Operator(plan_ir.MAP, f"annotate-{tag}", "v", "a"),
    ]
    if reduce_tail:
        ops.append(plan_ir.Operator(plan_ir.REDUCE, f"count-{tag}", "v"))
    return plan_ir.LogicalPlan(tuple(ops))


def result_fingerprint(res):
    """Canonical byte-comparable key for an ExecutionResult of a
    :func:`tagged_plan` run (reduce scalar, or rowids + mapped column)."""
    from repro.core import executor as ex
    if res.is_reduce:
        return ("reduce", res.scalar)
    return ("table", tuple(res.table.columns[ex.ROWID]),
            tuple(map(str, res.table.columns["a"])))


class EmbeddingOracle:
    """Deterministic seedable encoder for ``core.cascade`` tests/benches.

    Implements the cascade ``Encoder`` protocol with hash-derived unit
    vectors whose cosine against the operator anchor *correlates with the
    capability simulator's difficulty draws*: a value with difficulty
    ``d`` (the exact ``_unit_hash("difficulty", ...)`` draw the
    :class:`~repro.core.backends.SimulatedBackend` uses) embeds at

        cos = sign * (base + spread * (1 - d))

    where ``sign`` is +1 iff the oracle's true answer is truthy. Easy
    records sit far from the decision boundary, hard ones near it — so
    band routing is testable end-to-end without a real encoder, and
    :meth:`bands_for` can place thresholds such that every on-device
    resolution targets a record the given backend answers correctly
    (making cascade and no-cascade results identical at
    ``violation_rate=0``)."""

    def __init__(self, oracle, seed: int = 0, dim: Optional[int] = None,
                 base: float = 0.15, spread: float = 0.80):
        from repro.core import semhash
        self.oracle = oracle
        self.seed = seed
        self.dim = dim if dim is not None else semhash.DIM
        self.base = base
        self.spread = spread

    def _unit(self, *parts) -> np.ndarray:
        h = hashlib.blake2b("\x1f".join(map(str, parts)).encode(),
                            digest_size=8).digest()
        rng = np.random.default_rng(int.from_bytes(h, "little"))
        v = rng.standard_normal(self.dim)
        return v / np.linalg.norm(v)

    def encode_anchor(self, op) -> np.ndarray:
        return self._unit("anchor", self.seed, op.kind,
                          op.instruction).astype(np.float32)

    def encode_values(self, op, values: Sequence) -> np.ndarray:
        a = self._unit("anchor", self.seed, op.kind, op.instruction)
        rows = []
        for v in values:
            diff = bk._unit_hash("difficulty", self.seed, op.kind,
                                 op.instruction, v)
            truth = self.oracle.answer(op, v)
            sign = 1.0 if bool(truth) else -1.0
            cos = sign * min(0.999,
                             self.base + self.spread * (1.0 - diff))
            b = self._unit("tangent", self.seed, op.kind, str(v))
            b = b - float(b @ a) * a
            b = b / np.linalg.norm(b)
            rows.append(cos * a + math.sqrt(max(0.0, 1.0 - cos * cos)) * b)
        return np.asarray(rows, np.float32)

    def bands_for(self, op, backend, batch_size: int = 1,
                  margin: float = 0.02):
        """Bands under which every on-device resolution hits a record
        ``backend`` answers correctly: resolved => |cos| >= hi =>
        difficulty <= cap - margin/spread < cap => correct (at
        ``violation_rate=0``), so cascade results match no-cascade
        byte-for-byte while everything easier than the backend's
        effective capability skips the LLM."""
        from repro.core.cascade import CascadeBands
        cap = backend._capability(op, batch_size) \
            if hasattr(backend, "_capability") else 1.0
        cap = min(max(cap, 0.0), 1.0)
        hi = min(0.999, self.base + self.spread * (1.0 - cap) + margin)
        return CascadeBands(lo=-hi, hi=hi)


class SleepBackend:
    """Always-correct fake backend whose calls *really* sleep.

    Each (batched) call bills ``delay_s`` metered latency — exactly like
    SimulatedBackend bills its modeled latency — and sleeps ``sleep_s``
    real seconds (defaults to ``delay_s``; pass ``sleep_s=0.0`` for
    event-time-only tests that want 1s modeled calls without 1s waits).
    Counts calls and records each call's value group under a lock, so
    tests can assert the exact batch grouping the runtime formed."""

    def __init__(self, oracle, delay_s: float = 0.05, name: str = "m*",
                 capability: float = 1.01,
                 sleep_s: Optional[float] = None):
        self.tier = TierSpec(name, capability, 0.0, 0.0, delay_s, 0.0)
        self.oracle = oracle
        self.delay_s = delay_s
        self.sleep_s = delay_s if sleep_s is None else sleep_s
        self.calls_made = 0
        self.groups = []
        self._lock = threading.Lock()

    def __getstate__(self):
        # picklable for the ``procs`` driver's worker processes; answers
        # are value-derived (oracles are stateless), so a shipped copy
        # answers identically to the coordinator's original
        state = dict(self.__dict__)
        del state["_lock"]
        return state

    def __setstate__(self, state):
        self.__dict__.update(state)
        self._lock = threading.Lock()

    def run_values(self, op, values: Sequence, meter=None,
                   batch_size: int = 1):
        values = list(values)
        if op.kind == plan_ir.REDUCE:
            n_calls = 1
            outs = [self.oracle.answer_reduce(op, values)]
        else:
            n_calls = max(1, -(-len(values) // batch_size))
            outs = [self.oracle.answer(op, v) for v in values]
        with self._lock:
            self.calls_made += n_calls
            self.groups.append(tuple(map(str, values)))
        if self.sleep_s:
            time.sleep(self.sleep_s * n_calls)
        if meter is not None:
            meter.record(self.tier.name,
                         bk.Usage(calls=n_calls, tok_in=8.0 * len(values),
                                  tok_out=4.0 * n_calls, usd=0.0,
                                  latency_s=self.delay_s * n_calls),
                         per_call_latency_s=[self.delay_s] * n_calls,
                         op_kind=op.kind)
        return outs


class FlakyBackend:
    """Deterministic chaos wrapper around any backend — the fault plan
    is a pure function of ``(seed, logical call key)``.

    Each ``run_values`` call draws ``u = _unit_hash("fault-plan", seed,
    key)`` where the key is the ambient :meth:`UsageMeter.current_key`
    the runtime installs around every backend call. Logical keys are
    driver-, shard-count- and admission-order-invariant, and retry
    attempts carry their own ``(RETRY_KEY_MARK, attempt)`` suffix — so a
    fixed ``(seed, rates)`` plan injects the same faults into the same
    logical calls under any scheduling, and a retried call draws fresh.
    Bands (in order): ``u < error_rate`` raises
    :class:`runtime.TransientCallError`; next ``timeout_rate`` raises
    :class:`runtime.CallTimeoutError` (billing the call's deadline as
    its latency); next ``slow_rate`` sleeps ``slow_s`` real seconds
    (only when ``real_sleep``) then answers normally. ``poison_values``
    fail *every* attempt — the permanent-failure band retries cannot
    mask (used by the coalescer-poison regression tests).

    Faulted attempts are billed as one call with ``op_kind=None``: they
    land in the call log and the spend totals (retries are not free),
    but :meth:`CostModel.observe` skips them, so fault noise never
    corrupts the latency/q-error EWMAs."""

    def __init__(self, inner, *, error_rate: float = 0.0,
                 timeout_rate: float = 0.0, slow_rate: float = 0.0,
                 slow_s: float = 0.0, seed: int = 0,
                 fault_latency_s: float = 0.01,
                 poison_values=(), real_sleep: bool = False):
        self.inner = inner
        self.tier = inner.tier
        self.error_rate = error_rate
        self.timeout_rate = timeout_rate
        self.slow_rate = slow_rate
        self.slow_s = slow_s
        self.seed = seed
        self.fault_latency_s = fault_latency_s
        self.poison_values = frozenset(map(str, poison_values))
        self.real_sleep = real_sleep
        self.calls_seen = 0
        self.faults_injected = 0
        self._lock = threading.Lock()
        self._anon_attempts: dict = {}

    def __getstate__(self):
        # fault plans are pure functions of (seed, logical key) via a
        # content hash — a pickled copy in a worker process draws the
        # exact same plan, so chaos runs stay deterministic over the wire
        state = dict(self.__dict__)
        del state["_lock"]
        return state

    def __setstate__(self, state):
        self.__dict__.update(state)
        self._lock = threading.Lock()

    def __getattr__(self, name):
        # delegate capability probes etc. (_capability, oracle, ...);
        # never delegate dunders (pickle probes __reduce_ex__ machinery
        # before __dict__ exists — delegating would recurse on `inner`)
        if name.startswith("__") or "inner" not in self.__dict__:
            raise AttributeError(name)
        return getattr(self.inner, name)

    def _ident(self, op, values, meter):
        """Logical identity of this call for the fault draw."""
        key = meter.current_key() if meter is not None else None
        if key is not None:
            return key
        # no ambient key (bare run_values outside the runtime): fall
        # back to content identity plus a per-identity attempt counter
        # so repeated identical calls still draw independently
        base = (op.kind, op.instruction, tuple(map(str, values)))
        with self._lock:
            n = self._anon_attempts.get(base, 0)
            self._anon_attempts[base] = n + 1
        return base + (n,)

    def _bill_fault(self, op, values, meter, latency_s: float):
        with self._lock:
            self.faults_injected += 1
        if meter is None:
            return
        tok_in = 8.0 * len(list(values))
        meter.record(self.tier.name,
                     bk.Usage(calls=1, tok_in=tok_in, tok_out=0.0,
                              usd=self.tier.usd(tok_in, 0.0),
                              latency_s=latency_s),
                     per_call_latency_s=[latency_s],
                     op_kind=None)

    def run_values(self, op, values: Sequence, meter=None,
                   batch_size: int = 1):
        values = list(values)
        with self._lock:
            self.calls_seen += 1
        if self.poison_values and any(str(v) in self.poison_values
                                      for v in values):
            self._bill_fault(op, values, meter, self.fault_latency_s)
            raise rt.TransientCallError(
                f"poisoned value in {op.kind}:{op.instruction}")
        u = bk._unit_hash("fault-plan", self.seed,
                          repr(self._ident(op, values, meter)))
        if u < self.error_rate:
            self._bill_fault(op, values, meter, self.fault_latency_s)
            raise rt.TransientCallError(
                f"injected transient error (u={u:.3f})")
        if u < self.error_rate + self.timeout_rate:
            budget = rt.current_call_timeout()
            self._bill_fault(op, values, meter,
                             budget if budget is not None
                             else self.fault_latency_s)
            raise rt.CallTimeoutError(
                f"injected timeout (u={u:.3f})")
        if u < self.error_rate + self.timeout_rate + self.slow_rate \
                and self.real_sleep and self.slow_s:
            time.sleep(self.slow_s)
        return self.inner.run_values(op, values, meter=meter,
                                     batch_size=batch_size)


# One lock per *process* (module-level: spawn re-imports this module in
# each worker, so every worker process gets its own). GilBoundBackend
# holds it across its modeled compute — the GIL model below.
_GIL_MODEL_LOCK = threading.Lock()


class GilBoundBackend:
    """Always-correct fake whose per-call work is *GIL-bound by model*:
    each call sleeps ``work_s`` while holding the process-global
    :data:`_GIL_MODEL_LOCK`.

    Why model instead of burning CPU: the bench containers often expose
    a single core, where real CPU-bound work cannot show parallel
    speedup for *any* execution substrate — the measurement would say
    nothing about the GIL. This fake models the GIL's defining property
    directly, the same way :class:`SleepBackend` models I/O with
    ``time.sleep``: within one Python process, concurrent calls
    serialize on the lock exactly as bytecode serializes on the GIL
    (threads driver: total wall ≥ calls × ``work_s`` regardless of pool
    width); across ``procs`` workers, each spawned process re-imports
    this module and gets its *own* lock, so calls overlap exactly as
    separate interpreters escape each other's GIL. ``bench_shard.py``
    uses it to locate the thread-scaling knee and the process-worker
    speedup past it.

    Billing mirrors :class:`SleepBackend` (``work_s`` metered latency
    per call, deterministic token counts), so invariance assertions
    compare byte-identically across drivers and shard counts."""

    def __init__(self, oracle, work_s: float = 0.004, name: str = "m*",
                 capability: float = 1.01):
        self.tier = TierSpec(name, capability, 0.0, 0.0, work_s, 0.0)
        self.oracle = oracle
        self.work_s = work_s
        self.calls_made = 0
        self._lock = threading.Lock()

    def __getstate__(self):
        state = dict(self.__dict__)
        del state["_lock"]
        return state

    def __setstate__(self, state):
        self.__dict__.update(state)
        self._lock = threading.Lock()

    def run_values(self, op, values: Sequence, meter=None,
                   batch_size: int = 1):
        values = list(values)
        if op.kind == plan_ir.REDUCE:
            n_calls = 1
            outs = [self.oracle.answer_reduce(op, values)]
        else:
            n_calls = max(1, -(-len(values) // batch_size))
            outs = [self.oracle.answer(op, v) for v in values]
        for _ in range(n_calls):
            with _GIL_MODEL_LOCK:      # "hold the GIL" for the work
                time.sleep(self.work_s)
        with self._lock:
            self.calls_made += n_calls
        if meter is not None:
            meter.record(self.tier.name,
                         bk.Usage(calls=n_calls, tok_in=8.0 * len(values),
                                  tok_out=4.0 * n_calls, usd=0.0,
                                  latency_s=self.work_s * n_calls),
                         per_call_latency_s=[self.work_s] * n_calls,
                         op_kind=op.kind)
        return outs
